"""Config registry: ``get_config(arch_id)`` for every assigned architecture.

Arch ids use the assignment's dashed names; module files use underscores.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.types import ModelConfig, ShapeConfig
from repro.configs.shapes import (  # noqa: F401  (re-exported)
    ASSIGNED_SHAPES, CLIMBER_BASE, CLIMBER_LONG, DECODE_32K, LONG_500K,
    PREFILL_32K, SHAPES, TRAIN_4K, get_shape)

_ARCH_MODULES = {
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-7b": "rwkv6_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-32b": "qwen1_5_32b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma3-12b": "gemma3_12b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "climber": "climber",
}

ASSIGNED_ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "climber"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _ARCH_MODULES}


def reduced_config(arch: str) -> ModelConfig:
    """Smoke-test variant: 2 layers (1 pattern period if longer), d_model<=512,
    <=4 experts — runs a real forward/train step on CPU."""
    import dataclasses
    cfg = get_config(arch)
    # Compress the layer pattern to its distinct kinds so the reduced model
    # stays at 2 layers while still exercising every layer type.
    pattern = tuple(dict.fromkeys(cfg.layer_pattern))
    if len(pattern) == 1:
        pattern = pattern * 2
    n_layers = len(pattern)
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, max(1, n_heads // cfg.q_per_kv if cfg.q_per_kv else n_heads))
    n_kv = max(1, min(n_kv, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = max(8, d_model // n_heads)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, num_experts=4, top_k=min(moe.top_k, 2),
                                  d_ff_expert=min(moe.d_ff_expert, 512))
    climber = cfg.climber
    if climber is not None:
        climber = dataclasses.replace(climber, layers_per_block=2)
        n_layers = 2
    return dataclasses.replace(
        cfg,
        layer_pattern=pattern,
        n_layers=n_layers,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        frontend_tokens=min(cfg.frontend_tokens, 16) if cfg.frontend_tokens else 0,
        moe=moe,
        climber=climber,
        rwkv_head_size=min(cfg.rwkv_head_size, head_dim),
    )
