"""qwen1.5-32b [dense] — MHA-style GQA (kv=40) with QKV bias.

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064  [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    activation="swiglu",
    norm="rmsnorm",
    layer_pattern=("attn",),
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-0.5B",
)
