"""Climber — the paper's own GR model (FLAME's serving workload).

The paper (Table 2) specifies 2 blocks x 12 layers and the SUMI scenarios
base (512 history + 128 candidates, 3.72 GFLOPs) / long (1024 + 512,
16.4 GFLOPs).  d_model is not published; d_model=256 reproduces the paper's
per-request GFLOPs to within ~2x and is recorded as an estimate in DESIGN.md.
Item/user features enter through an embedding table (vocab = item catalog).
"""
from repro.types import ModelConfig, ClimberConfig

CONFIG = ModelConfig(
    name="climber",
    family="climber",
    n_layers=12,                 # per block; ClimberConfig.num_blocks blocks
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=2_000_000,        # item catalog size (music platform scale)
    activation="gelu",
    norm="layernorm",
    layer_pattern=("attn",),
    climber=ClimberConfig(num_blocks=2, layers_per_block=12,
                          num_tasks=3, num_experts_head=4,
                          adaptive_temperature=True),
    sub_quadratic=False,
    source="arXiv:2502.09888 (Climber) / FLAME Table 2",
)
