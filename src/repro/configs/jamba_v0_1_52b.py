"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887]
Pattern period 8: [mamba x3, attn, mamba x4]; MoE every 2nd layer.
Mamba-dominant -> runs long_500k (attn layers keep seq-sharded caches).
"""
from repro.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, every_n_layers=2),
    mamba_d_state=16,
    mamba_expand=2,
    mamba_d_conv=4,
    sub_quadratic=True,
    source="arXiv:2403.19887",
)
