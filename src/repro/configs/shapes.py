"""Assigned input shapes + the paper's own SUMI scenarios."""
from repro.types import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524288, global_batch=1, kind="decode")

# Paper scenarios (Table 2): SUMI serving — history + candidates per request.
CLIMBER_BASE = ShapeConfig(name="climber_base", seq_len=512, global_batch=32,
                           kind="prefill", n_candidates=128)
CLIMBER_LONG = ShapeConfig(name="climber_long", seq_len=1024, global_batch=32,
                           kind="prefill", n_candidates=512)

SHAPES = {s.name: s for s in
          [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, CLIMBER_BASE, CLIMBER_LONG]}

ASSIGNED_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
