"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206  [arXiv:2308.11596]
Interpreted as 12 encoder + 12 decoder layers (24 total; see DESIGN.md §4).

The mel-spectrogram + conformer feature extractor is a stub per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(seq_len // 4 frames, mimicking 4x conv downsampling).
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=12,            # decoder layers
    n_enc_layers=12,        # encoder layers (12+12 = assigned 24L)
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    norm="layernorm",
    rope_theta=0.0,         # learned/sinusoidal positions; 0 disables RoPE
    layer_pattern=("attn",),
    modality="audio",
    frontend_tokens=0,      # dynamic: seq_len // 4 frames
    sub_quadratic=False,
    source="arXiv:2308.11596",
)
