"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE with early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E]

Alternating dense/MoE FFN (every 2nd layer MoE) with one shared expert,
following the Maverick interleave.  Early fusion: multimodal tokens enter the
shared embedding stream (text-only here; vision stub supplies embeddings).
"""
from repro.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    activation="swiglu",
    norm="rmsnorm",
    layer_pattern=("attn", "attn"),   # period 2: dense FFN / MoE FFN interleave
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  every_n_layers=2, num_shared_experts=1),
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
