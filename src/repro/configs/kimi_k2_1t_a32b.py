"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384 experts top-8
[arXiv:2501.kimi2]

Every layer is MoE with one shared expert (DeepSeek-V3-style), d_ff_expert=2048.
"""
from repro.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    activation="swiglu",
    norm="rmsnorm",
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  every_n_layers=1, num_shared_experts=1),
    tie_embeddings=False,
    sub_quadratic=False,
    source="arXiv:2501.kimi2",
)
