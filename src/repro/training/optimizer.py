"""AdamW implemented from scratch (optax is not available in this env).

States mirror the param pytree so they inherit the same shardings; the
update is fully jittable and donation-friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, dict, dict]:
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = _schedule(cfg, opt_state["step"])

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step)
        nu_hat = nu / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"grad_norm": gn, "lr": lr}
