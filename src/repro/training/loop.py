"""Training loop: jitted train_step composition + host-side driver."""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelBundle
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    impl: str = "chunked") -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics), jittable."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: bundle.loss_fn(p, batch, impl=impl), has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def train(bundle: ModelBundle, batches: Iterator[Dict], n_steps: int,
          opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
          log_every: int = 10, impl: str = "chunked",
          params=None, callback: Optional[Callable] = None):
    """Host driver: returns (params, opt_state, history)."""
    opt_cfg = opt_cfg or AdamWConfig()
    if params is None:
        params, _ = bundle.init(jax.random.key(seed))
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(bundle, opt_cfg, impl=impl),
                      donate_argnums=(0, 1))
    history = []
    t0 = time.perf_counter()
    for step in range(n_steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            metrics["wall_s"] = time.perf_counter() - t0
            history.append(metrics)
            if callback:
                callback(metrics)
    jax.block_until_ready(params)
    return params, opt_state, history
