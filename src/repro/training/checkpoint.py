"""Msgpack checkpointing (orbax is not available in this env).

Arrays are serialized with dtype/shape preserved (bf16 via uint16 view).
Layout: one file per checkpoint, {step, tree: flattened {path: array}}.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_array(a) -> Dict[str, Any]:
    a = np.asarray(a)
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": a.dtype.str, "shape": list(a.shape), "data": a.tobytes()}


def _decode_array(d) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(path: str, tree, step: int = 0):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {"step": step,
               "tree": {k: _encode_array(v) for k, v in _flatten(tree).items()}}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat = {k: _decode_array(v) for k, v in payload["tree"].items()}
    keys = list(_flatten(like).keys())
    missing = [k for k in keys if k not in flat]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves_like, treedef = jax.tree.flatten(like)
    restored = [jnp.asarray(flat[k]) for k in keys]
    return treedef.unflatten(restored), payload["step"]
