"""Synthetic data pipelines.

GRInteractionDataset — generative-recommendation interaction sequences with a
planted preference structure so the Climber model has real signal to learn:
each user has a latent taste vector; items have latent embeddings; history is
sampled by taste affinity and labels (click/like/finish) are Bernoulli in the
user-item affinity.  Zipf-distributed item popularity drives realistic cache
hit-rates for the PDA benchmark.

TokenDataset — LM token streams (markov-chain bigram structure, so loss can
fall below ln(V)) for the text-decoder architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class GRInteractionDataset:
    n_items: int = 100_000
    n_users: int = 10_000
    latent_dim: int = 16
    num_tasks: int = 3
    zipf_a: float = 1.3
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.item_latent = rng.standard_normal(
            (self.n_items, self.latent_dim)).astype(np.float32)
        self.user_latent = rng.standard_normal(
            (self.n_users, self.latent_dim)).astype(np.float32)
        self.task_bias = np.linspace(-1.0, 1.0, self.num_tasks).astype(np.float32)

    def _popular_items(self, rng, size) -> np.ndarray:
        return (rng.zipf(self.zipf_a, size=size) - 1) % self.n_items

    def sample_request(self, rng: np.random.Generator, n_history: int,
                       n_candidates: int) -> Dict[str, np.ndarray]:
        uid = rng.integers(self.n_users)
        taste = self.user_latent[uid]
        # history: popularity mixed with taste affinity
        pool = self._popular_items(rng, n_history * 4)
        aff = self.item_latent[pool] @ taste
        p = np.exp(aff - aff.max())
        p /= p.sum()
        history = rng.choice(pool, size=n_history, p=p)
        candidates = self._popular_items(rng, n_candidates)
        logits = self.item_latent[candidates] @ taste * 0.7
        labels = (rng.random((n_candidates, self.num_tasks))
                  < _sigmoid(logits[:, None] + self.task_bias)).astype(np.float32)
        side = np.concatenate([taste[:8], [n_history / 1024, n_candidates / 1024,
                                           1.0, 0.0]]).astype(np.float32)
        return {"history": history.astype(np.int32),
                "candidates": candidates.astype(np.int32),
                "side": side, "labels": labels, "user_id": uid}

    def batch(self, rng, batch_size: int, n_history: int, n_candidates: int
              ) -> Dict[str, np.ndarray]:
        reqs = [self.sample_request(rng, n_history, n_candidates)
                for _ in range(batch_size)]
        return {k: np.stack([r[k] for r in reqs]) for k in
                ("history", "candidates", "side", "labels")}


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


@dataclasses.dataclass
class TokenDataset:
    """Markov bigram token stream: learnable structure for LM smoke training."""

    vocab_size: int = 1024
    branching: int = 8          # successors per token
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.successors = rng.integers(
            0, self.vocab_size, (self.vocab_size, self.branching)).astype(np.int32)

    def batch(self, rng, batch_size: int, seq_len: int) -> Dict[str, np.ndarray]:
        toks = np.empty((batch_size, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, batch_size)
        for t in range(1, seq_len):
            pick = rng.integers(0, self.branching, batch_size)
            toks[:, t] = self.successors[toks[:, t - 1], pick]
        return {"tokens": toks}


def make_batch_iterator(dataset, batch_size: int, seed: int = 0,
                        **kw) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        yield dataset.batch(rng, batch_size, **kw)
