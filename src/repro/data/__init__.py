from repro.data.synthetic import (  # noqa: F401
    GRInteractionDataset, TokenDataset, make_batch_iterator)
