"""Tracing-time flags.

COST_TRANSPARENT: when set (by the dry-run's roofline variants), sequence
scans (chunked attention KV loop, RWKV chunk loop, layer stacks) lower fully
unrolled so XLA cost analysis sees every iteration — a while-loop body is
otherwise counted once regardless of trip count.
"""
import contextlib
import contextvars

COST_TRANSPARENT = contextvars.ContextVar("repro_cost_transparent",
                                          default=False)


@contextlib.contextmanager
def cost_transparent():
    tok = COST_TRANSPARENT.set(True)
    try:
        yield
    finally:
        COST_TRANSPARENT.reset(tok)


def unroll_scans() -> bool:
    return COST_TRANSPARENT.get()


# MoE dispatch implementation: "gspmd" (scatter/gather, partitioner-chosen
# collectives) or "a2a" (explicit shard_map all_to_all expert parallelism —
# the §Perf optimized path).
MOE_DISPATCH = contextvars.ContextVar("repro_moe_dispatch", default="gspmd")


@contextlib.contextmanager
def moe_dispatch(kind: str):
    assert kind in ("gspmd", "a2a")
    tok = MOE_DISPATCH.set(kind)
    try:
        yield
    finally:
        MOE_DISPATCH.reset(tok)
