import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, with ShapeDtypeStruct stand-ins (no allocation), and record
memory/cost/collective analysis for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--fast]

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count at first backend init.  Smoke tests / benches import repro.* directly
and keep seeing 1 device.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import roofline as RL
from repro import sharding as shd
from repro.configs import (ASSIGNED_ARCHS, ASSIGNED_SHAPES, get_config,
                           get_shape)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.loop import make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def per_chip_bytes(shapes_tree, shardings_tree) -> float:
    """Actual bytes resident per chip given resolved shardings."""
    import numpy as np
    total = 0.0
    for sds, sh in zip(jax.tree.leaves(shapes_tree),
                       jax.tree.leaves(shardings_tree)):
        shard = sh.shard_shape(sds.shape)
        total += float(np.prod(shard)) * sds.dtype.itemsize
    return total


def abstract_init(bundle) -> Tuple[Dict, Dict]:
    """Parameter ShapeDtypeStructs + logical specs WITHOUT allocating."""
    box = {}

    def f(key):
        params, specs = bundle.init(key)
        box["specs"] = specs
        return params

    shapes = jax.eval_shape(f, jax.random.key(0))
    return shapes, box["specs"]


def abstract_caches(bundle, batch: int, max_len: int,
                    quant: bool = False) -> Tuple[Dict, Dict]:
    box = {}

    def f():
        caches, specs = bundle.cache_init(batch, max_len, quant=quant)
        box["specs"] = specs
        return caches

    shapes = jax.eval_shape(f)
    return shapes, box["specs"]


def _input_shardings(bundle, shape, mesh, rules):
    specs = bundle.input_specs(shape)
    logical = bundle.input_logical(shape)
    return {k: shd.logical_to_sharding(logical.get(k, (None,) * len(v.shape)),
                                       v.shape, mesh, rules)
            for k, v in specs.items()}, specs


def _lower_and_compile(cfg, shape, mesh, rules, attention_impl: str,
                       kv_quant: bool = False):
    """Build + AOT-compile the step function for one workload."""
    t0 = time.perf_counter()
    bundle = build_model(cfg)
    param_shapes, param_specs = abstract_init(bundle)
    param_sh = shd.tree_shardings(param_specs, param_shapes, mesh, rules)
    in_sh, in_specs = _input_shardings(bundle, shape, mesh, rules)

    with shd.mesh_rules(mesh, rules):
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(adamw_init, param_shapes)
            opt_specs = {"mu": param_specs, "nu": param_specs, "step": ()}
            opt_sh = shd.tree_shardings(opt_specs, opt_shapes, mesh, rules)
            step_fn = make_train_step(bundle, AdamWConfig(),
                                      impl=attention_impl)
            jitted = jax.jit(step_fn,
                             in_shardings=(param_sh, opt_sh, in_sh),
                             out_shardings=(param_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(param_shapes, opt_shapes, in_specs)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return bundle.prefill(params, batch, impl=attention_impl)
            jitted = jax.jit(prefill_fn, in_shardings=(param_sh, in_sh))
            lowered = jitted.lower(param_shapes, in_specs)
        else:  # decode: serve_step = ONE token against a seq_len KV cache
            cache_shapes, cache_specs = abstract_caches(
                bundle, shape.global_batch, shape.seq_len, quant=kv_quant)
            cache_sh = shd.tree_shardings(cache_specs, cache_shapes, mesh,
                                          rules)

            def serve_step(params, caches, batch):
                return bundle.decode_step(params, caches, batch,
                                          impl="reference")
            jitted = jax.jit(serve_step,
                             in_shardings=(param_sh, cache_sh, in_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_shapes, cache_shapes, in_specs)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    return compiled, t_lower, t_compile


def _extrapolated_cost(cfg, shape, mesh, rules, attention_impl: str,
                       n_groups: int, kv_quant: bool = False) -> Dict:
    """Per-partition flops/bytes/collective-bytes, scan-trip-count corrected:
    lower 1-group and 2-group variants, total = c1 + (n_groups-1)*(c2-c1)."""
    from repro import flags
    vals = {}
    for k in (1, 2):
        ck = _with_layers(cfg, k)
        with flags.cost_transparent():
            compiled, _, _ = _lower_and_compile(ck, shape, mesh, rules,
                                                attention_impl, kv_quant)
        cost = compiled.cost_analysis() or {}
        coll = RL.collective_bytes_from_hlo(compiled.as_text())
        vals[k] = {"flops": float(cost.get("flops", 0.0)),
                   "bytes": float(cost.get("bytes accessed", 0.0)),
                   "coll": coll}
    out = {}
    for key in ("flops", "bytes"):
        delta = max(vals[2][key] - vals[1][key], 0.0)
        out[key] = vals[1][key] + (n_groups - 1) * delta
    detail = {}
    for k in vals[1]["coll"]:
        if k == "counts":
            continue
        delta = max(vals[2]["coll"][k] - vals[1]["coll"][k], 0.0)
        detail[k] = vals[1]["coll"][k] + (n_groups - 1) * delta
    out["collective_bytes"] = detail["total"]
    out["collective_detail"] = detail
    return out


def _with_layers(cfg, k_groups: int):
    """cfg with k layer-pattern groups (enc-dec: k enc + k dec layers)."""
    import dataclasses
    period = len(cfg.layer_pattern)
    rep = {"n_layers": k_groups * period}
    if cfg.enc_dec:
        rep["n_enc_layers"] = k_groups * period
    if cfg.climber is not None:
        rep["n_layers"] = k_groups
        rep["climber"] = dataclasses.replace(cfg.climber,
                                             layers_per_block=k_groups)
    return dataclasses.replace(cfg, **rep)


def should_skip(cfg, shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §4)")
    return None


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               save: bool = True, verbose: bool = True,
               fsdp: bool = True, extra_tag: str = "",
               attention_impl: str = "chunked",
               rules_override: Optional[Dict] = None,
               moe_dispatch: str = "gspmd", kv_quant: bool = False) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{mesh_name}_{arch}_{shape_name}{extra_tag}"
    skip = should_skip(cfg, shape)
    if skip:
        rec = {"tag": tag, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "skipped", "reason": skip}
        if save:
            _save(tag, rec)
        if verbose:
            print(f"[dryrun] SKIP {tag}: {skip}")
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = shd.rules_for_shape(mesh, shape.global_batch, fsdp=fsdp)
    if rules_override:
        names = set(mesh.axis_names)
        rules.update({k: tuple(a for a in v if a in names)
                      for k, v in rules_override.items()})

    # ---- 1. full-config compile: proves the (arch x shape x mesh) lowers;
    #         source of memory_analysis ----
    from repro import flags as _flags
    _moe_tok = _flags.MOE_DISPATCH.set(moe_dispatch)
    try:
        compiled, t_lower, t_compile = _lower_and_compile(
            cfg, shape, mesh, rules, attention_impl, kv_quant)
        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {k: getattr(mem, k) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
                     if hasattr(mem, k)}
        except Exception as e:  # noqa: BLE001
            mem, mem_d = None, {"error": str(e)}
        hlo = compiled.as_text()

        # ---- 2. roofline terms: XLA cost analysis counts a scan body ONCE,
        #         so the layer scan under-counts flops/bytes/collectives by
        #         the trip count.  Lower 1-group and 2-group variants and
        #         extrapolate total = base + n_groups * delta. ----
        n_groups = cfg.n_groups if cfg.climber is None else \
            cfg.climber.layers_per_block
        ext = _extrapolated_cost(cfg, shape, mesh, rules, attention_impl,
                                 n_groups, kv_quant)
        # actual per-chip weight/cache residency for the memory estimate
        _bundle = build_model(cfg)
        _pshapes, _pspecs = abstract_init(_bundle)
        params_bytes_chip = per_chip_bytes(
            _pshapes, shd.tree_shardings(_pspecs, _pshapes, mesh, rules))
        cache_bytes_chip = None
        if shape.kind == "decode":
            _cshapes, _cspecs = abstract_caches(_bundle, shape.global_batch,
                                                shape.seq_len, quant=kv_quant)
            cache_bytes_chip = per_chip_bytes(
                _cshapes, shd.tree_shardings(_cspecs, _cshapes, mesh, rules))
    finally:
        _flags.MOE_DISPATCH.reset(_moe_tok)

    report = RL.analyse(arch, shape_name, mesh_name, chips,
                        {"flops": ext["flops"],
                         "bytes accessed": ext["bytes"]},
                        "", cfg, shape,
                        per_device_peak_memory=mem_d.get("temp_size_in_bytes"),
                        params_bytes_chip=params_bytes_chip,
                        cache_bytes_chip=cache_bytes_chip)
    # collective bytes were extrapolated per-partition already
    report.collective_bytes = ext["collective_bytes"] * chips
    report.collective_s = report.collective_bytes / (chips * RL.TPU_V5E.ici_bw)
    report.collective_detail = ext["collective_detail"]
    rec = {
        "tag": tag, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "utilization operand 0")
                          if k in cost},
        "roofline": report.to_dict(),
        "hlo_bytes_len": len(hlo),
    }
    if save:
        _save(tag, rec)
    if verbose:
        print(f"[dryrun] OK {tag}: chips={chips} "
              f"compile={t_compile:.1f}s "
              f"mem={mem_d} "
              f"flops={report.hlo_flops:.3e} "
              f"compute={report.compute_s*1e3:.2f}ms "
              f"memory_xla={report.memory_s*1e3:.2f}ms "
              f"memory_est={report.memory_s_est*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"dominant={report.dominant} useful={report.useful_ratio:.2f}")
    return rec


def _save(tag: str, rec: Dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--missing", action="store_true",
                    help="skip combinations that already have a result file")
    ap.add_argument("--moe-dispatch", default="gspmd",
                    choices=["gspmd", "a2a"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default="",
                    help='logical-rule overrides, e.g. "experts=data;seq=model"')
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (decode shapes)")
    ap.add_argument("--profile", default=None, choices=[None, "serving"],
                    help="apply the §Perf-optimized sharding profile")
    args = ap.parse_args()
    overrides = None
    if args.rules:
        overrides = {}
        for kv in args.rules.split(";"):
            k, v = kv.split("=")
            overrides[k.strip()] = tuple(a for a in v.split(",") if a)
    if args.profile == "serving":
        # hillclimb-2 outcome: TP-resident weights, sequence-sharded KV cache
        args.no_fsdp = True
        overrides = dict(overrides or {})
        overrides.setdefault("cache_seq", ("model",))

    jobs = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in ASSIGNED_SHAPES:
                for mp in meshes:
                    jobs.append((a, s.name, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            jobs.append((args.arch, args.shape, mp))

    if args.missing:
        def _exists(a, s, mp):
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            return os.path.exists(os.path.join(
                RESULTS_DIR, f"{mesh_name}_{a}_{s}.json"))
        jobs = [j for j in jobs if not _exists(*j)]
        print(f"[dryrun] {len(jobs)} missing jobs to run")

    failures = []
    for a, s, mp in jobs:
        try:
            dryrun_one(a, s, multi_pod=mp, fsdp=not args.no_fsdp,
                       attention_impl=args.impl,
                       moe_dispatch=args.moe_dispatch, extra_tag=args.tag,
                       rules_override=overrides, kv_quant=args.kv_quant)
        except Exception as e:  # noqa: BLE001
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] FAIL {a} {s} multi_pod={mp}: {e}")
            traceback.print_exc()
    print(f"[dryrun] done: {len(jobs) - len(failures)}/{len(jobs)} ok")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
