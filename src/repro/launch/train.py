"""Training launcher.

Host-scale (this container):
    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-3-4b \
        --reduced --steps 100 --batch 8 --seq 128

Pod-scale: the same entry point with --mesh pod16x16 builds the production
mesh sharding (on real TPU hardware); on CPU use launch/dryrun.py to verify
the pod configuration compiles.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import sharding as shd
from repro.configs import get_config, reduced_config
from repro.data import GRInteractionDataset, TokenDataset, make_batch_iterator
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.training import checkpoint
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None, help="checkpoint path to write")
    ap.add_argument("--mesh", default="host", choices=["host", "pod16x16",
                                                       "pod2x16x16"])
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    print(f"[train] arch={cfg.name} reduced={args.reduced} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    if cfg.family == "climber":
        ds = GRInteractionDataset(n_items=cfg.vocab_size)
        it = make_batch_iterator(ds, args.batch, n_history=args.seq,
                                 n_candidates=max(4, args.seq // 8))
        impl = "reference"
    else:
        ds = TokenDataset(vocab_size=cfg.vocab_size, branching=8)
        it = make_batch_iterator(ds, args.batch, seq_len=args.seq)
        impl = "chunked"

    def log(m):
        print(f"[train] step={m['step']:<5d} loss={m['loss']:.4f} "
              f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
              f"wall={m['wall_s']:.1f}s")

    params, opt_state, hist = train(
        bundle, it, args.steps,
        AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 10)),
        log_every=max(1, args.steps // 20), impl=impl, callback=log)

    if args.ckpt:
        checkpoint.save(args.ckpt, params, step=args.steps)
        print(f"[train] checkpoint written to {args.ckpt}")
    print(f"[train] done: first loss {hist[0]['loss']:.4f} -> "
          f"final {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
