"""Production mesh definitions (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (critical: the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    data = max(1, n // model_parallel)
    return make_mesh((data, model_parallel), ("data", "model"))
