"""Production mesh definitions (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (critical: the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    data = max(1, n // model_parallel)
    return make_mesh((data, model_parallel), ("data", "model"))


def make_serving_mesh(mesh: str = "", model_parallel: int = 0):
    """Resolve the serve CLI's mesh flags to a ("data", "model") host mesh.

    ``mesh``: explicit "DATA,MODEL" ways (e.g. "2,2").  ``model_parallel``:
    shortcut — KV heads sharded N ways, data ways = devices // N.  Both
    empty/zero -> None (single-device serving).  On CPU hosts pair with
    XLA_FLAGS=--xla_force_host_platform_device_count=K set before jax import.
    """
    if mesh:
        parts = [int(x) for x in mesh.split(",")]
        if len(parts) != 2 or any(p < 1 for p in parts):
            raise ValueError(f"--mesh expects 'data,model' ways, got {mesh!r}")
        return make_mesh(tuple(parts), ("data", "model"))
    if model_parallel:
        return make_host_mesh(model_parallel)
    return None
