"""Serving launcher: the full FLAME pipeline under synthetic traffic.

    PYTHONPATH=src python -m repro.launch.serve --requests 32 \
        --buckets 64,32,16 --feature-mode sync --distribution jittered
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data import GRInteractionDataset
from repro.models import build_model
from repro.serving import FlameEngine
from repro.serving.scheduler import TrafficConfig, generate_traffic, run_workload
from repro.training import checkpoint
from repro.types import ClimberConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--history", type=int, default=128)
    ap.add_argument("--buckets", default="64,32,16")
    ap.add_argument("--counts", default="16,32,64")
    ap.add_argument("--distribution", default="uniform",
                    choices=["uniform", "zipf", "jittered"])
    ap.add_argument("--feature-mode", default="sync",
                    choices=["off", "sync", "async"])
    ap.add_argument("--streams", type=int, default=2)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--ckpt", default=None, help="restore params from here")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=50_000, d_model=args.d_model,
        d_ff=4 * args.d_model, n_heads=4, n_kv_heads=4,
        head_dim=args.d_model // 4,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    if args.ckpt:
        params, step = checkpoint.restore(args.ckpt, params)
        print(f"[serve] restored checkpoint @ step {step}")

    buckets = tuple(int(b) for b in args.buckets.split(","))
    eng = FlameEngine(bundle, params, n_history=args.history,
                      buckets=buckets, n_streams=args.streams,
                      feature_mode=args.feature_mode)
    print(f"[serve] executor pool built in {eng.pool.build_time_s:.2f}s "
          f"(buckets {buckets} x {args.streams} streams)")

    tc = TrafficConfig(
        candidate_counts=tuple(int(c) for c in args.counts.split(",")),
        distribution=args.distribution, n_requests=args.requests,
        n_history=args.history, seed=0)
    reqs = generate_traffic(tc, n_items=cfg.vocab_size)
    res = run_workload(lambda h, c: eng.serve(h, c), reqs,
                       concurrency=args.concurrency)
    print(f"[serve] {res['requests']} requests | "
          f"{res['throughput_items_per_s']:.0f} items/s | "
          f"mean {res['mean_latency_ms']:.1f} ms | "
          f"p99 {res['p99_latency_ms']:.1f} ms")
    print(f"[serve] feature cache: {eng.features.stats}")
    print(f"[serve] dso chunks: {eng.dso.chunk_count}")
    eng.shutdown()


if __name__ == "__main__":
    main()
