"""Serving launcher: any registered engine under synthetic traffic.

    PYTHONPATH=src python -m repro.launch.serve --engine flame \
        --requests 32 --buckets 64,32,16 --distribution jittered
    PYTHONPATH=src python -m repro.launch.serve --engine flame \
        --history-cache --pool-slots 128 --users 8 --requests 64
    PYTHONPATH=src python -m repro.launch.serve --engine flame \
        --generate topk --gen-steps 8     # generative candidate decode
    PYTHONPATH=src python -m repro.launch.serve --engine flame \
        --generate beam --beam-width 4
    PYTHONPATH=src python -m repro.launch.serve --engine flame \
        --generate topk --impl fused --pool-dtype int8   # FKE v2 decode
    PYTHONPATH=src python -m repro.launch.serve --engine implicit
    PYTHONPATH=src python -m repro.launch.serve --engine text --arch gemma3-12b

Engines are selected by name through the API v2 registry
(repro.serving.api); requests are driven through ``submit`` so cross-request
chunk coalescing is exercised for the flame engine.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serving import ServeRequest, available_engines, create_engine
from repro.serving.api import BeamConfig, DegradationPolicy, TopKConfig
from repro.serving.faults import FaultInjector
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)
from repro.training import checkpoint
from repro.types import ClimberConfig


def _print_metrics(tag: str, m: dict):
    print(f"[serve] {tag}: " + ", ".join(
        f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
        for k, v in sorted(m.items())))


def _parse_kv_floats(spec: str, what: str) -> dict:
    """Parse ``name=value,name=value`` CLI maps (tier deadlines, mixes)."""
    out = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise SystemExit(f"[serve] bad {what} entry {part!r} "
                             f"(want name=value)")
        k, v = part.split("=", 1)
        out[k.strip()] = float(v)
    return out


def serve_text(args):
    cfg = reduced_config(args.arch)
    print(f"[serve] text engine on reduced {cfg.name}: {cfg.n_layers}L "
          f"d={cfg.d_model} pattern={cfg.layer_pattern}")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    eng = create_engine("text", bundle, params, batch=2, max_len=128)
    rng = np.random.default_rng(0)
    futs = [eng.submit(ServeRequest(
        history=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
        n_tokens=args.tokens)) for _ in range(args.requests)]
    for f in futs:
        r = f.result()
        print(f"[serve] req {r.request_id}: generated {r.output.tolist()} "
              f"in {r.latency_s * 1e3:.0f} ms")
    _print_metrics("metrics", eng.metrics())
    eng.shutdown()


def serve_rec(args):
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=50_000, d_model=args.d_model,
        d_ff=4 * args.d_model, n_heads=4, n_kv_heads=4,
        head_dim=args.d_model // 4,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    if args.ckpt:
        params, step = checkpoint.restore(args.ckpt, params)
        print(f"[serve] restored checkpoint @ step {step}")

    gen_mode = getattr(args, "generate", "none")
    if gen_mode != "none" and args.engine == "flame":
        if not args.history_cache:
            print("[serve] --generate implies --history-cache (beams live "
                  "in the pooled-KV plane); enabling it")
            args.history_cache = True

    kw = dict(n_history=args.history, feature_mode=args.feature_mode,
              max_pending=args.max_pending, impl=args.impl)
    if args.engine == "flame":
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh, args.model_parallel)
        kw.update(mesh=mesh,
                  buckets=tuple(int(b) for b in args.buckets.split(",")),
                  n_streams=args.streams, coalesce=not args.no_coalesce,
                  max_batch=args.max_batch,
                  window_s=args.window_ms * 1e-3,
                  n_workers=args.concurrency,
                  history_cache=args.history_cache,
                  pool_slots=args.pool_slots,
                  pool_budget_bytes=(int(args.pool_budget_mb * 2**20)
                                     if args.pool_budget_mb else None),
                  pool_dtype=args.pool_dtype,
                  pool_placement=args.pool_placement,
                  pool_spill_bytes=int(args.pool_spill_mb * 2**20),
                  incremental_history=args.incremental_history,
                  extend_buckets=(tuple(int(b) for b in
                                        args.extend_buckets.split(",")
                                        if b.strip())
                                  if args.extend_buckets.strip() else None),
                  extend_refresh_limit=args.extend_refresh_limit,
                  pack_tails=args.pack_tails,
                  pack_rows=args.pack_rows if args.pack_rows > 0 else None,
                  pack_align=args.pack_align if args.pack_align > 0
                  else None,
                  deadline_s=args.deadline_ms * 1e-3)
        # ---- overload discipline / fault tolerance (ISSUE 9) ----
        tier_defaults = None
        if args.slo_tier_defaults.strip():
            tier_defaults = {k: v * 1e-3 for k, v in _parse_kv_floats(
                args.slo_tier_defaults, "--slo-tier-defaults").items()}
        degradation = None
        if args.degrade > 0:
            degradation = DegradationPolicy(threshold_s=args.degrade * 1e-3)
        faults = None
        if args.fault_spec.strip():
            faults = FaultInjector.parse(args.fault_spec,
                                         seed=args.fault_seed)
        kw.update(admission=args.admission, shed_policy=args.shed_policy,
                  slo_tier_defaults=tier_defaults,
                  watchdog_grace_s=args.watchdog_grace_ms * 1e-3,
                  degradation=degradation, faults=faults)
        if gen_mode != "none":
            kw.update(generate=args.gen_steps, gen_vocab=args.gen_vocab)
    else:
        kw.update(n_workers=args.concurrency)
    eng = create_engine(args.engine, bundle, params, **kw)
    if args.engine == "flame":
        fams = ", ".join(f"{k}:{v}" for k, v in eng.dso.families.items())
        print(f"[serve] executor pool built in {eng.dso.build_time_s:.2f}s "
              f"(families {fams}, impl {args.impl}, "
              f"batch axis {eng.dso.policy.batch}, "
              f"coalesce={'on' if eng.dso.policy.enabled else 'off'}, "
              f"pack_tails={'on' if args.pack_tails else 'off'}, "
              f"deadline={args.deadline_ms:g}ms)")
        if eng.mesh is not None:
            print(f"[serve] mesh: data={eng.mesh.shape['data']} x "
                  f"model={eng.mesh.shape['model']} over "
                  f"{len(jax.devices())} {jax.default_backend()} device(s)")
        if args.history_cache:
            budget = (f"{args.pool_budget_mb:g} MB budget"
                      if args.pool_budget_mb else "no byte budget")
            print(f"[serve] history-KV pool: {args.pool_slots} slots, "
                  f"{budget}, dtype {args.pool_dtype}, "
                  f"placement {args.pool_placement}, incremental="
                  f"{'on' if args.incremental_history else 'off'}")

    tier_mix = _parse_kv_floats(args.slo_mix, "--slo-mix") \
        if args.slo_mix.strip() else None
    tc = TrafficConfig(
        candidate_counts=tuple(int(c) for c in args.counts.split(",")),
        distribution=args.distribution, n_requests=args.requests,
        n_history=args.history, seed=0, n_users=args.users,
        tier_mix=tier_mix)
    reqs = generate_traffic(tc, n_items=cfg.vocab_size)
    if gen_mode != "none":
        # generative decode: the traffic's ragged candidate slates become
        # per-request token universes (zipf/jittered slate sizes -> ragged
        # decode dispatches), and each request asks for top-k or beam
        # generation instead of scoring
        gen_eos = args.gen_eos if args.gen_eos >= 0 else None
        gen_cfg = (TopKConfig(k=args.beam_width, steps=args.gen_steps,
                              eos=gen_eos)
                   if gen_mode == "topk" else
                   BeamConfig(width=args.beam_width, steps=args.gen_steps,
                              eos=gen_eos))
        for r in reqs:
            r["generate"] = gen_cfg
        print(f"[serve] generative decode: {gen_mode} width "
              f"{args.beam_width} x {args.gen_steps} steps, per-request "
              f"token universes from the candidate slates")
    # chaos / overload runs tolerate rejections and injected failures —
    # the liveness contract they DO assert is zero hung futures: every
    # submitted request resolves, errors included, inside the timeout
    chaos = args.engine == "flame" and (bool(args.fault_spec.strip())
                                        or args.shed_policy != "none")
    res = run_workload_async(eng, reqs,
                             arrival_gap_s=args.arrival_gap_ms * 1e-3,
                             tolerate_errors=chaos)
    unit = "gen tokens/s" if gen_mode != "none" else "items/s"
    print(f"[serve] {res['requests']} requests | "
          f"{res['throughput_items_per_s']:.0f} {unit} | "
          f"p50 {res['p50_latency_ms']:.1f} ms | "
          f"p99 {res['p99_latency_ms']:.1f} ms")
    if chaos:
        hint = (f" retry_after~{res['retry_after_mean_ms']:.0f}ms "
                f"(x{res['retry_after_hinted']})"
                if res.get("retry_after_hinted") else "")
        print(f"[serve] overload/chaos accounting: "
              f"resolved={res['resolved']} rejected={res['rejected']} "
              f"failed={res['failed']} hung={res['hung']}{hint}")
        if res["hung"]:
            _print_metrics("engine metrics", eng.metrics())
            raise SystemExit(f"[serve] LIVENESS VIOLATION: {res['hung']} "
                             f"future(s) never resolved")
    if gen_mode != "none":
        for i, out in enumerate(res["outputs"][:3]):
            best = [t for t in out[0].tolist() if t >= 0]
            print(f"[serve] req {i}: best sequence {best}")
    _print_metrics("engine metrics", eng.metrics())
    eng.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="flame",
                    choices=list(available_engines()))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--history", type=int, default=128)
    ap.add_argument("--buckets", default="64,32,16")
    ap.add_argument("--counts", default="16,32,64")
    ap.add_argument("--distribution", default="uniform",
                    choices=["uniform", "zipf", "jittered", "lognormal"])
    ap.add_argument("--feature-mode", default="sync",
                    choices=["off", "sync", "async"])
    ap.add_argument("--impl", default="chunked",
                    choices=["reference", "chunked", "pallas", "fused"],
                    help="attention impl for the model forward (chunked "
                         "avoids O(S^2) score materialization on CPU; "
                         "fused is the FKE candidate-scoring engine — "
                         "cached scoring reads quantized pool KV and the "
                         "dedup row index in-kernel)")
    ap.add_argument("--history-cache", action="store_true",
                    help="split the SUMI forward: pool per-user history KV, "
                         "serve candidate-only executors on pool hits")
    ap.add_argument("--pool-slots", type=int, default=256,
                    help="history-KV pool capacity (entries, LRU-evicted)")
    ap.add_argument("--pool-budget-mb", type=float, default=0.0,
                    help="history-KV pool byte budget in MB (0 = entry "
                         "bound only); LRU-evicts by bytes_used")
    ap.add_argument("--pool-dtype", default="native",
                    choices=["native", "bf16", "int8"],
                    help="stored precision of pool entries (int8 uses "
                         "per-head scales; ~2x users per byte budget vs "
                         "the bf16-native entries, ~4x vs f32)")
    ap.add_argument("--pool-placement", default="device",
                    choices=["device", "host"],
                    help="device keeps entries as JAX device arrays (no "
                         "host round-trip per dispatch); host is the "
                         "legacy PR 2 behavior")
    ap.add_argument("--pool-spill-mb", type=float, default=0.0,
                    help="host-RAM second-tier budget in MB absorbing "
                         "pool evictions (0 = no spill tier)")
    ap.add_argument("--incremental-history", action="store_true",
                    help="on stale pool hits sharing a window prefix with "
                         "the cached entry, re-encode only the suffix + "
                         "side token against the cached prefix K/V")
    ap.add_argument("--extend-buckets", default="",
                    help="comma list of trusted-prefix lengths for the "
                         "extend executor family (empty = the default "
                         "ladder n,3n/4,n/2; prefixes below n/2 re-encode "
                         "— the crossover policy)")
    ap.add_argument("--extend-refresh-limit", type=int, default=0,
                    help="force a full re-encode after this many "
                         "incremental extensions of one pool entry (bounds "
                         "requantization drift under --pool-dtype int8; "
                         "0 = uncapped)")
    ap.add_argument("--pack-tails", action="store_true",
                    help="DSO v2 segment packing (needs --history-cache): "
                         "partial tail chunks from different requests pack "
                         "into shared (1, bucket) rows, each candidate "
                         "segment steered to its own user's pooled history "
                         "KV — reclaims the padding the greedy bucket "
                         "split dispatches on non-uniform traffic")
    ap.add_argument("--pack-rows", type=int, default=0,
                    help="row capacity of the packed executors (packed "
                         "rows are dense, so fewer rows carry the same "
                         "candidate throughput at less executor cost; "
                         "--max-batch still sizes how many distinct users "
                         "one packed dispatch can steer to; 0 = auto "
                         "max_batch/4)")
    ap.add_argument("--pack-align", type=int, default=0,
                    help="start every packed candidate segment on a "
                         "multiple of this (multiple of 8; 1 = plain "
                         "first-fit): aligned segments are constant per "
                         "fused q-block, so packed 2-D dispatches keep the "
                         "kernel formulation instead of rerouting to jnp "
                         "(0 = auto: 8 under --impl fused --pack-tails, "
                         "else 1)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="default per-request deadline budget: pending "
                         "chunks flush earliest-deadline-first and the "
                         "DSO stops collecting co-riders once its cost "
                         "model says waiting longer would miss the "
                         "earliest deadline (0 = no deadlines; misses "
                         "surface as the deadline_misses metric)")
    ap.add_argument("--admission", default="edf", choices=["edf", "fifo"],
                    help="admission queue order: edf serves earliest "
                         "absolute deadline first (ties: better SLO tier, "
                         "then arrival); fifo is the arrival-order baseline")
    ap.add_argument("--slo-tier-defaults", default="",
                    help="per-tier default deadline budgets in ms, e.g. "
                         "'interactive=50,standard=250,bulk=2000'; applied "
                         "when a request carries no explicit deadline "
                         "(empty = only --deadline-ms applies)")
    ap.add_argument("--shed-policy", default="none",
                    choices=["none", "tiered"],
                    help="tiered: when the queue is at depth or the "
                         "EWMA-predicted wait blows an arrival's budget, "
                         "fail the worst lower-priority queued request "
                         "(ShedError, shed_{tier} counters) instead of "
                         "blocking everyone")
    ap.add_argument("--degrade", type=float, default=0.0,
                    help="graceful-degradation queue-delay threshold in ms "
                         "(0 = off): a sustained delay EWMA above it steps "
                         "the service level down — 1: flush coalescing "
                         "windows immediately, 2: + bulk generation at "
                         "half width/steps, 3: + bulk encodes become "
                         "cached-hit-or-shed; recovery reverses the steps")
    ap.add_argument("--slo-mix", default="",
                    help="traffic tier mix as weights, e.g. "
                         "'interactive=0.2,standard=0.5,bulk=0.3' "
                         "(empty = all standard)")
    ap.add_argument("--watchdog-grace-ms", type=float, default=0.0,
                    help="fail any future still unresolved this long past "
                         "its deadline with WatchdogTimeout (0 = no "
                         "watchdog); the liveness backstop under faults")
    ap.add_argument("--fault-spec", default="",
                    help="chaos injection arms, e.g. "
                         "'dispatch:0.2,stall:0.1:0.02,evict:0.1' "
                         "(see repro.serving.faults); deterministic per "
                         "--fault-seed.  The launcher then tolerates "
                         "failures but exits non-zero if any future hangs")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="PRNG seed for --fault-spec arms")
    ap.add_argument("--generate", default="none",
                    choices=["none", "topk", "beam"],
                    help="generative candidate decode (needs "
                         "--history-cache, auto-enabled): serve "
                         "autoregressive top-k / beam generation over the "
                         "item vocabulary from pooled history KV instead "
                         "of scoring candidate slates; the traffic's "
                         "candidate ids become per-request token universes")
    ap.add_argument("--gen-steps", type=int, default=8,
                    help="generated sequence length (also sizes the "
                         "decode executors' KV headroom)")
    ap.add_argument("--beam-width", type=int, default=4,
                    help="hypotheses kept per step (beam width for "
                         "--generate beam, k for --generate topk)")
    ap.add_argument("--gen-eos", type=int, default=-1,
                    help="EOS item id: a hypothesis emitting it finishes "
                         "early, and once every hypothesis has finished "
                         "the remaining decode rounds are skipped "
                         "(gen_early_exits metric; -1 = no EOS)")
    ap.add_argument("--gen-vocab", type=int, default=512,
                    help="fallback token-universe size when a generative "
                         "request carries no candidate restriction")
    ap.add_argument("--mesh", default="",
                    help="serve the flame executors over a 'data,model' "
                         "device mesh, e.g. --mesh 2,2: the request batch "
                         "axis is sharded over data ways and attention "
                         "heads over model ways, with pooled history KV "
                         "committed to the same layout (empty = no mesh; "
                         "on CPU hosts set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=K first)")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="shortcut for --mesh: shard KV heads over N model "
                         "ways, data ways = devices // N")
    ap.add_argument("--users", type=int, default=0,
                    help="repeat-user traffic: draw requests from this many "
                         "users with stable histories (0 = unique users)")
    ap.add_argument("--streams", type=int, default=2)
    ap.add_argument("--concurrency", type=int, default=4,
                    help="pipeline worker threads")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable cross-request chunk coalescing")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="coalescing fill target / executor batch axis")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="coalescing time window")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="admission queue bound (backpressure)")
    ap.add_argument("--arrival-gap-ms", type=float, default=0.0,
                    help="max random gap between request arrivals")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--ckpt", default=None, help="restore params from here")
    ap.add_argument("--arch", default="gemma3-12b",
                    help="text engine: reduced config name")
    ap.add_argument("--tokens", type=int, default=12,
                    help="text engine: tokens per request")
    args = ap.parse_args()

    if args.engine == "text":
        serve_text(args)
    else:
        serve_rec(args)


if __name__ == "__main__":
    main()
