"""Core configuration dataclasses for the repro framework.

Everything downstream (model zoo, kernels, serving, dry-run) is driven by two
frozen dataclasses: :class:`ModelConfig` (architecture) and :class:`ShapeConfig`
(workload shape).  Configs for the assigned architectures live in
``repro.configs`` and are plain instances of these types.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (None on dense models)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    # A layer ``i`` is an MoE layer iff ``i % every_n_layers == every_n_layers-1``
    # (jamba: every 2nd layer; kimi/llama4: every layer).
    every_n_layers: int = 1
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class ClimberConfig:
    """Paper-specific settings for the Climber GR model (FLAME's workload)."""

    num_blocks: int = 2          # N_b independent transformer blocks
    layers_per_block: int = 12
    num_tasks: int = 3           # multi-task expert head outputs
    num_experts_head: int = 4    # expert MLPs in the top-level head
    adaptive_temperature: bool = True


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``layer_pattern`` is a repeating period of layer kinds; entries are
    ``"attn"`` (global attention), ``"swa"`` (sliding window attention),
    ``"mamba"`` or ``"rwkv"``.  ``n_layers`` must be a multiple of the pattern
    length so the stack lowers as a ``lax.scan`` over pattern groups.
    """

    name: str
    family: str                     # dense | vlm | ssm | audio | moe | hybrid | climber
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    activation: str = "swiglu"      # swiglu | gelu | relu
    rope_theta: float = 1e6
    sliding_window: int = 0         # window for "swa" layers (0 = unused)
    layer_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    climber: Optional[ClimberConfig] = None
    # --- encoder-decoder (audio) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    # --- modality stubs ---
    modality: str = "text"          # text | vision | audio
    frontend_tokens: int = 0        # patch/frame tokens provided by the stub frontend
    # --- long-context eligibility ---
    sub_quadratic: bool = False
    # --- misc ---
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    source: str = ""                # citation bracket from the assignment
    # --- rwkv specifics ---
    rwkv_head_size: int = 64
    # --- mamba specifics ---
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"layer_pattern length {len(self.layer_pattern)}")
        if self.moe is not None and len(self.layer_pattern) % self.moe.every_n_layers != 0:
            raise ValueError(f"{self.name}: MoE period must divide layer pattern period")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        per_attn = (self.n_heads * hd + 2 * self.n_kv_heads * hd) * d + self.n_heads * hd * d
        n_gate = 2 if self.activation == "swiglu" else 1
        per_dense_ffn = (n_gate + 1) * d * f
        n_attn = sum(1 for k in self.layer_pattern if k in ("attn", "swa")) * self.n_groups
        n_mamba = sum(1 for k in self.layer_pattern if k == "mamba") * self.n_groups
        n_rwkv = sum(1 for k in self.layer_pattern if k == "rwkv") * self.n_groups
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        total += n_attn * per_attn
        d_in = self.mamba_expand * d
        total += n_mamba * (2 * d * d_in + d_in * d + d_in * (2 * self.mamba_d_state + 1))
        total += n_rwkv * (4 * d * d + d * d)  # r,k,v,g,o projections approx
        if self.moe is None:
            total += self.n_layers * per_dense_ffn
        else:
            n_moe = self.n_layers // self.moe.every_n_layers
            n_plain = self.n_layers - n_moe
            per_expert = (n_gate + 1) * d * self.moe.d_ff_expert
            total += n_moe * (self.moe.num_experts + self.moe.num_shared_experts) * per_expert
            total += n_moe * d * self.moe.num_experts  # router
            total += n_plain * per_dense_ffn
        if self.enc_dec:
            # decoder cross-attention adds one attention block per decoder layer
            total += self.n_layers * per_attn
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_gate = 2 if self.activation == "swiglu" else 1
        per_expert = (n_gate + 1) * d * self.moe.d_ff_expert
        n_moe = self.n_layers // self.moe.every_n_layers
        inactive = n_moe * (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(self.param_count() - inactive)


@dataclass(frozen=True)
class ShapeConfig:
    """A workload shape from the assignment (or a paper scenario)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    # Climber/SUMI scenarios: candidates scored in parallel per request.
    n_candidates: int = 0

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for the target chip (TPU v5e by default)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link

TPU_V5E = HardwareSpec()
