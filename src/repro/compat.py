"""JAX version-compat shims.

``jax.sharding.AxisType`` (explicit/auto mesh axis types) only exists on
newer JAX.  On older versions every mesh axis is implicitly "auto", so the
correct downlevel behaviour is simply to omit the kwarg.  All mesh
construction in the repo (and in test subprocess scripts) goes through
:func:`make_mesh`, and all shard_map use through :func:`shard_map`, so the
version split lives in exactly one place.
"""
from __future__ import annotations

import jax


def mesh_axis_types(n: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` on new JAX, ``{}`` on old."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    return jax.make_mesh(shape, axis_names,
                         **mesh_axis_types(len(axis_names)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX; ``jax.experimental.shard_map`` (whose
    replication check is spelled ``check_rep``) on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
